"""GHD compiler: cyclic join-aggregate queries vs the brute-force oracle."""
import numpy as np
import pytest

from repro.aggregates.semiring import Avg, Max, Min, Sum
from repro.core.operator import choose_root, estimate_plan, join_agg
from repro.core.prepare import prepare
from repro.core.query import JoinAggQuery
from repro.data.queries import CYCLIC
from repro.ghd.bags import MAX_DENSE_ELEMS
from repro.ghd.hypertree import build_ghd, verify_ghd
from repro.ghd.rewrite import compile_ghd, is_cyclic_query
from repro.relational.oracle import oracle_joinagg
from repro.relational.relation import Database

from tests.test_joinagg_core import assert_same

RNG = np.random.default_rng(7)
ENGINES = ("tensor", "ref", "jax")


def small_graph(n=250, nodes=20, labels=4, seed=2):
    rng = np.random.default_rng(seed)
    return (
        rng.integers(0, nodes, n),
        rng.integers(0, nodes, n),
        rng.integers(0, labels, nodes),
    )


def triangle_db(n=250, nodes=20, labels=4, seed=2):
    src, dst, lab = small_graph(n, nodes, labels, seed)
    db = Database.from_mapping(
        {
            "E1": {"a": src, "b": dst},
            "E2": {"b": src, "c": dst},
            "E3": {"c": src, "a": dst},
            "L": {"a": np.arange(nodes), "vlabel": lab},
        }
    )
    return db, JoinAggQuery(("E1", "E2", "E3", "L"), (("L", "vlabel"),))


def four_cycle_db(n=220, nodes=18, labels=4, seed=3):
    src, dst, lab = small_graph(n, nodes, labels, seed)
    db = Database.from_mapping(
        {
            "E1": {"a": src, "b": dst},
            "E2": {"b": src, "c": dst},
            "E3": {"c": src, "d": dst},
            "E4": {"d": src, "a": dst},
            "L": {"a": np.arange(nodes), "lab": lab},
        }
    )
    return db, JoinAggQuery(("E1", "E2", "E3", "E4", "L"), (("L", "lab"),))


# --- acceptance: cyclic queries ran nowhere before, now match the oracle ---


def test_cyclic_was_a_hard_error():
    db, q = triangle_db()
    assert is_cyclic_query(q, db)
    with pytest.raises(ValueError, match="cyclic"):
        prepare(q, db)  # the paper-scope pipeline still rejects it


@pytest.mark.parametrize("engine", ENGINES)
def test_triangle_counts_match_oracle(engine):
    db, q = triangle_db()
    assert_same(join_agg(q, db, engine=engine), oracle_joinagg(q, db))


@pytest.mark.parametrize("engine", ENGINES)
def test_four_cycle_counts_match_oracle(engine):
    db, q = four_cycle_db()
    assert_same(join_agg(q, db, engine=engine), oracle_joinagg(q, db))


_CATALOG_CACHE: dict = {}


def _catalog_case(name):
    if name not in _CATALOG_CACHE:
        db, q = CYCLIC[name](n=220, seed=5)
        _CATALOG_CACHE[name] = (db, q, oracle_joinagg(q, db, lenient=True))
    return _CATALOG_CACHE[name]


@pytest.mark.parametrize("name", list(CYCLIC))
@pytest.mark.parametrize("engine", ENGINES)
def test_cyclic_catalog_matches_oracle(name, engine):
    db, q, want = _catalog_case(name)
    assert_same(join_agg(q, db, engine=engine), want)


# --- column-copy convention: group attr participates in the cyclic join ---


@pytest.mark.parametrize("engine", ENGINES)
def test_four_cycle_per_vertex(engine):
    db, _ = four_cycle_db()
    q = JoinAggQuery(("E1", "E2", "E3", "E4"), (("E1", "a"),))
    want = oracle_joinagg(q, db, lenient=True)
    assert_same(join_agg(q, db, engine=engine), want)


def bowtie_db(n=200, nodes=15, seed=4):
    """Two triangles sharing vertex ``a`` — any min-width GHD keeps one bag
    per triangle, so the group attr ``a`` spans both bags and must be
    column-copied."""
    rng = np.random.default_rng(seed)
    def cols(x, y):
        return {x: rng.integers(0, nodes, n), y: rng.integers(0, nodes, n)}

    db = Database.from_mapping(
        {
            "E1": cols("a", "b"), "E2": cols("b", "c"), "E3": cols("c", "a"),
            "E4": cols("a", "d"), "E5": cols("d", "e"), "E6": cols("e", "a"),
        }
    )
    return db, JoinAggQuery(tuple(f"E{i}" for i in range(1, 7)), (("E1", "a"),))


@pytest.mark.parametrize("engine", ENGINES)
def test_bowtie_per_vertex_column_copy(engine):
    db, q = bowtie_db()
    plan = compile_ghd(q, db)
    assert plan.copied_attrs == {"a": "a__grp"}  # group attr joined two bags
    want = oracle_joinagg(q, db, lenient=True)
    assert_same(join_agg(q, db, engine=engine), want)


@pytest.mark.parametrize("engine", ENGINES)
def test_triangle_per_vertex_single_bag(engine):
    db, _ = triangle_db()
    q = JoinAggQuery(("E1", "E2", "E3"), (("E1", "a"),))
    want = oracle_joinagg(q, db, lenient=True)
    assert_same(join_agg(q, db, engine=engine), want)


def test_same_attr_grouped_from_two_relations_gets_distinct_copies():
    """Grouping the shared ``grp`` attr from both G1 and G2 must yield two
    distinct copy columns (identical names would silently join the copies)."""
    rng = np.random.default_rng(9)
    n, people, groups = 150, 12, 5
    db = Database.from_mapping(
        {
            "F1": {"u": rng.integers(0, people, n), "v": rng.integers(0, people, n)},
            "F2": {"v": rng.integers(0, people, n), "w": rng.integers(0, people, n)},
            "G1": {"u": rng.integers(0, people, n), "grp": rng.integers(0, groups, n)},
            "G2": {"w": rng.integers(0, people, n), "grp": rng.integers(0, groups, n)},
        }
    )
    q = JoinAggQuery(("F1", "F2", "G1", "G2"), (("G1", "grp"), ("G2", "grp")))
    plan = compile_ghd(q, db)
    names = [a for _, a in plan.derived_query.group_by]
    assert len(set(names)) == 2
    want = oracle_joinagg(q, db, lenient=True)
    for engine in ENGINES:
        assert_same(join_agg(q, db, engine=engine), want)


# --- non-COUNT aggregates ride the same bag machinery ---


@pytest.mark.parametrize(
    "agg,engines",
    [
        (Sum("E2", "m"), ("tensor", "jax")),
        (Avg("E2", "m"), ("tensor",)),
        (Min("E2", "m"), ("tensor",)),
        (Max("E2", "m"), ("tensor",)),
    ],
)
def test_cyclic_aggregates(agg, engines):
    db, _ = triangle_db()
    db["E2"].columns["m"] = RNG.normal(size=db["E2"].num_rows).round(2)
    q = JoinAggQuery(("E1", "E2", "E3", "L"), (("L", "vlabel"),), agg)
    want = oracle_joinagg(q, db)
    for engine in engines:
        assert_same(join_agg(q, db, engine=engine), want)


# --- planner integration: GHD costs flow through estimate_plan ---


def test_estimate_plan_reports_ghd_peaks():
    db, q = triangle_db()
    prep, peak = estimate_plan(q, db)
    assert peak > 0
    plan = compile_ghd(q, db)
    assert plan.bag_peak_bytes > 0
    assert peak >= plan.bag_peak_bytes  # bag accounting folded into the estimate
    # the derived plan is a normal Prepared: same accounting as acyclic plans
    prep2, peak2 = choose_root(q, db)
    assert peak2 <= peak or peak2 == peak


def test_streaming_on_cyclic_matches_full():
    db, q = triangle_db()
    full = join_agg(q, db)
    tiny = join_agg(q, db, memory_budget=1024)  # forces group-axis streaming
    assert_same(tiny, full)


def test_bag_cap_raises_memory_error():
    db, q = triangle_db()
    with pytest.raises(MemoryError, match="MAX_DENSE_ELEMS"):
        compile_ghd(q, db, cap_rows=4)


def test_max_dense_elems_mirrors_jax_engine():
    from repro.core.jax_engine import MAX_DENSE_ELEMS as JAX_CAP

    assert MAX_DENSE_ELEMS == JAX_CAP


# --- hypertree construction invariants ---


def test_triangle_ghd_properties():
    edges = {
        "E1": frozenset({"a", "b"}),
        "E2": frozenset({"b", "c"}),
        "E3": frozenset({"c", "a"}),
        "L": frozenset({"a", "l"}),
    }
    domains = {"a": 20, "b": 20, "c": 20, "l": 4}
    rows = {"E1": 100, "E2": 100, "E3": 100, "L": 20}
    ghd = build_ghd(edges, domains, rows, group_of={"L": "l"})
    verify_ghd(ghd, edges)
    core = [b for b in ghd.order if {"a", "b", "c"} <= set(ghd.bags[b].attrs)]
    assert len(core) == 1  # the triangle collapses into one bag
    # tightest-cover estimate: |E| * |dom(c)| caps the dense a*b*c product
    assert ghd.est_elems[core[0]] <= 100 * 20


def test_ghd_of_acyclic_query_is_join_tree():
    # chain R1(g,p0) R2(p0,p1) R3(p1,h): GHD must not inflate bag count
    edges = {
        "R1": frozenset({"g", "p0"}),
        "R2": frozenset({"p0", "p1"}),
        "R3": frozenset({"p1", "h"}),
    }
    ghd = build_ghd(edges, {a: 8 for a in "g p0 p1 h".split()},
                    {r: 50 for r in edges}, group_of={"R1": "g", "R3": "h"})
    verify_ghd(ghd, edges)
    assert len(ghd.order) <= 3


def test_acyclic_queries_keep_old_path():
    rng = np.random.default_rng(0)
    n, a, b = 150, 5, 7
    db = Database.from_mapping(
        {
            "R1": {"g1": rng.integers(0, a, n), "p": rng.integers(0, b, n)},
            "R2": {"p": rng.integers(0, b, n), "g2": rng.integers(0, a, n)},
        }
    )
    q = JoinAggQuery(("R1", "R2"), (("R1", "g1"), ("R2", "g2")))
    assert not is_cyclic_query(q, db)
    assert_same(join_agg(q, db), oracle_joinagg(q, db))
