"""Benchmark harness: one function per paper table.

Prints ``name,us_per_call,derived`` CSV.  ``--scale paper`` uses the
paper's 500k rows/relation (slow on 1 CPU); the default is
container-friendly and preserves every selectivity ratio.
"""
from __future__ import annotations

import argparse

from benchmarks import tables


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["small", "medium", "paper"], default="small")
    ap.add_argument("--table", choices=["1", "2", "3", "4", "5", "6", "7"], default=None)
    ap.add_argument("--no-verify", action="store_true")
    args = ap.parse_args()

    n_self = {"small": 20000, "medium": 100000, "paper": 500000}[args.scale]
    n_chain = {"small": 8000, "medium": 40000, "paper": 500000}[args.scale]
    n_branch = {"small": 6000, "medium": 30000, "paper": 500000}[args.scale]
    n_real = {"small": 20000, "medium": 100000, "paper": 500000}[args.scale]
    n_cyclic = {"small": 4000, "medium": 30000, "paper": 200000}[args.scale]
    verify = not args.no_verify and args.scale == "small"

    print("name,us_per_call,derived")
    run_all = args.table is None
    if run_all or args.table == "1":
        tables.table1_load(n_chain)
    if run_all or args.table == "3":
        tables.table3_selfjoin(n_self, verify)
    if run_all or args.table == "4":
        tables.table4_chain(n_chain, verify)
    if run_all or args.table == "5":
        tables.table5_branching(n_branch, verify)
    if run_all or args.table == "6":
        tables.table6_real(n_real, verify)
    if run_all or args.table == "7":
        tables.table7_cyclic(n_cyclic, verify)
    if run_all or args.table == "2":
        tables.table2_memory(n_branch)


if __name__ == "__main__":
    main()
