"""Benchmark harness: one function per paper table.

Prints ``name,us_per_call,derived`` CSV and always writes the same
records as machine-readable ``BENCH_<scale>.json`` (override the path
with ``--json-out``) so CI can archive a perf datapoint per PR.
``--scale paper`` uses the paper's 500k rows/relation (slow on 1 CPU);
``tiny`` is the CI smoke config; the default ``small`` is
container-friendly and preserves every selectivity ratio.
"""
from __future__ import annotations

import argparse

from benchmarks import common, tables

TABLES = [
    "1", "2", "3", "4", "5", "6", "7", "8", "9", "10", "11", "12", "13", "14",
    "15",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--scale", choices=["tiny", "small", "medium", "paper"], default="small"
    )
    ap.add_argument("--table", choices=TABLES, default=None)
    ap.add_argument("--no-verify", action="store_true")
    ap.add_argument(
        "--json-out", default=None,
        help="path for the JSON record dump (default BENCH_<scale>.json)",
    )
    args = ap.parse_args()

    n_self = {"tiny": 2000, "small": 20000, "medium": 100000, "paper": 500000}[args.scale]
    n_chain = {"tiny": 1500, "small": 8000, "medium": 40000, "paper": 500000}[args.scale]
    n_branch = {"tiny": 1500, "small": 6000, "medium": 30000, "paper": 500000}[args.scale]
    n_real = {"tiny": 2000, "small": 20000, "medium": 100000, "paper": 500000}[args.scale]
    n_cyclic = {"tiny": 1000, "small": 4000, "medium": 30000, "paper": 200000}[args.scale]
    verify = not args.no_verify and args.scale in ("tiny", "small")

    print("name,us_per_call,derived")
    run_all = args.table is None
    if run_all or args.table == "1":
        tables.table1_load(n_chain)
    if run_all or args.table == "3":
        tables.table3_selfjoin(n_self, verify)
    if run_all or args.table == "4":
        tables.table4_chain(n_chain, verify)
    if run_all or args.table == "5":
        tables.table5_branching(n_branch, verify)
    if run_all or args.table == "6":
        tables.table6_real(n_real, verify)
    if run_all or args.table == "7":
        tables.table7_cyclic(n_cyclic, verify)
    if run_all or args.table == "8":
        tables.table8_incremental(n_real, verify)
    if run_all or args.table == "9":
        tables.table9_multiagg(n_chain, verify)
    if run_all or args.table == "10":
        tables.table10_sparse(n_chain, verify)
    if run_all or args.table == "11":
        tables.table11_distributed(n_chain, verify)
    if run_all or args.table == "12":
        tables.table12_serving(n_chain, verify)
    if run_all or args.table == "13":
        tables.table13_planner(n_real, verify)
    if run_all or args.table == "14":
        tables.table14_storage(n_chain, verify)
    if run_all or args.table == "15":
        tables.table15_fused(n_chain, verify)
    if run_all or args.table == "2":
        tables.table2_memory(n_branch)

    out = args.json_out or f"BENCH_{args.scale}.json"
    common.write_json(
        out, scale=args.scale, table=args.table or "all", verify=verify
    )


if __name__ == "__main__":
    main()
