"""Plan-choice golden gate (DESIGN.md §10).

Renders ``Plan.explain(actuals=True)`` for every catalog query
(``repro.data.queries``: REAL + CYCLIC + SKEWED) at a tiny fixed scale
and compares against the checked-in snapshots in
``tests/goldens/plans/``.  The explain output carries every planner
decision — engine, root, GHD bag tree, stats summary, split ranges, jax
dense/sparse path, per-node byte + cardinality estimates — so any code
change that flips a plan choice shows up as a golden diff and fails CI
until the snapshot is regenerated *deliberately*:

    python -m benchmarks.plan_goldens --write   # regenerate snapshots
    python -m benchmarks.plan_goldens --check   # CI gate (default)

Scales are small enough to run in seconds yet large enough that the
skew/sparsity thresholds trigger exactly as they do at bench scale.
"""
from __future__ import annotations

import argparse
import difflib
import sys
from pathlib import Path

GOLDEN_DIR = Path(__file__).resolve().parent.parent / "tests" / "goldens" / "plans"

# per-catalog row counts: fixed forever — changing them rewrites history
SCALES = {"REAL": 600, "CYCLIC": 300, "SKEWED": 600}
ENGINE = "jax"  # the engine with the richest plan surface (path choice)


def catalog() -> dict[str, tuple[str, object]]:
    from repro.data.queries import CYCLIC, REAL, SKEWED

    out: dict[str, tuple[str, object]] = {}
    for group, cat in (("REAL", REAL), ("CYCLIC", CYCLIC), ("SKEWED", SKEWED)):
        for name, gen in sorted(cat.items()):
            out[name] = (group, gen)
    return out


def render(name: str, group: str, gen) -> str:
    from repro.api.builder import Q

    n = SCALES[group]
    db, q = gen(n, seed=0)
    # fused(True) so the kernels: section (per-hop megakernel tiles,
    # model-ranked — never the measurement cache) is golden-gated too
    plan = Q.from_query(q).engine(ENGINE).fused(True).plan(db)
    plan.verify()  # every golden plan must be invariant-clean (DESIGN.md §11)
    header = f"# plan golden: {name} ({group}, n={n}, engine={ENGINE}, fused)\n"
    return header + plan.explain(actuals=True) + "\n"


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument(
        "--write", action="store_true", help="regenerate every snapshot"
    )
    mode.add_argument(
        "--check", action="store_true", help="diff against snapshots (default)"
    )
    ap.add_argument("--only", default=None, help="restrict to one query name")
    args = ap.parse_args(argv)

    entries = catalog()
    if args.only:
        if args.only not in entries:
            print(f"unknown query {args.only!r}; have {sorted(entries)}")
            return 2
        entries = {args.only: entries[args.only]}

    if args.write:
        GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
        for name, (group, gen) in entries.items():
            path = GOLDEN_DIR / f"{name}.txt"
            path.write_text(render(name, group, gen))
            print(f"wrote {path}")
        return 0

    stale: list[str] = []
    for name, (group, gen) in entries.items():
        path = GOLDEN_DIR / f"{name}.txt"
        fresh = render(name, group, gen)
        if not path.exists():
            stale.append(name)
            print(f"MISSING golden for {name}: {path}")
            continue
        golden = path.read_text()
        if golden != fresh:
            stale.append(name)
            diff = difflib.unified_diff(
                golden.splitlines(keepends=True),
                fresh.splitlines(keepends=True),
                fromfile=f"golden/{name}.txt",
                tofile=f"fresh/{name}",
            )
            sys.stdout.writelines(diff)
            print()
    if not args.only:
        known = {f"{n}.txt" for n in catalog()}
        for extra in sorted(GOLDEN_DIR.glob("*.txt")):
            if extra.name not in known:
                stale.append(extra.name)
                print(f"ORPHAN golden {extra} (no catalog query produces it)")
    if stale:
        print(
            f"plan goldens: {len(stale)} stale/missing snapshot(s): "
            f"{sorted(stale)}\n"
            "a plan choice changed — if intended, regenerate with:\n"
            "    python -m benchmarks.plan_goldens --write"
        )
        return 1
    print(f"plan goldens: {len(entries)} snapshot(s) match")
    return 0


if __name__ == "__main__":
    sys.exit(main())
