"""Perf-regression gate: compare a fresh ``BENCH_*.json`` against the
checked-in baseline (``benchmarks/baseline_tiny.json``).

    python -m benchmarks.compare benchmarks/baseline_tiny.json BENCH_tiny.json

Per paper table the gate sums ``us_per_call`` over the records present in
*both* runs, normalizes the baseline by the machine-speed ratio of *the
other tables* (the two runs rarely share hardware — the baseline was
recorded on one container, CI runs on whatever runner it gets; excluding
the table under test keeps a heavy table's own regression from masking
itself), and **fails (exit 1) on any table whose normalized time
regressed by more than ``--threshold`` (default 30%)** and by more than
``--min-delta-us`` in absolute terms (tiny-scale tables of a few hundred
ms jitter past 30% run-to-run).  The normalization makes the gate catch
*relative* regressions — one code path getting slower than the rest of
the suite — which is the signature of a real perf bug; a uniform
machine-wide slowdown is invisible to it by design.

It also renders a markdown report — the per-table comparison, the
table-10 dense-vs-sparse peak-bytes delta, and the table-11 per-device
sharding peaks — into ``$GITHUB_STEP_SUMMARY`` when set (or
``--summary PATH``), so every PR shows its bench trajectory.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import defaultdict


def load_records(path: str) -> dict[str, dict]:
    with open(path) as f:
        doc = json.load(f)
    return {r["name"]: r for r in doc.get("records", [])}


def table_of(name: str) -> str:
    return name.split(",", 1)[0]


def table_totals(
    records: dict[str, dict], names: set[str]
) -> dict[str, float]:
    out: dict[str, float] = defaultdict(float)
    for name in names:
        out[table_of(name)] += records[name]["us_per_call"]
    return dict(out)


def derived_field(rec: dict | None, key: str) -> str | None:
    if rec is None:
        return None
    for part in rec.get("derived", "").split(";"):
        if part.startswith(key + "="):
            return part.split("=", 1)[1]
    return None


def sparse_delta_lines(fresh: dict[str, dict]) -> list[str]:
    """Table-10 dense-vs-sparse peak-bytes delta as markdown rows."""
    sparse = fresh.get("table10,CHAIN,jax_sparse")
    dense = fresh.get("table10,CHAIN,jax_dense")
    choice = fresh.get("table10,CHAIN,auto_choice")
    if not sparse or not choice:
        return ["_no table-10 records in this run_"]
    lines = [
        "| metric | dense | sparse |",
        "|---|---:|---:|",
        "| estimated peak (MB) | "
        f"{derived_field(choice, 'est_dense_mb')} | "
        f"{derived_field(choice, 'est_sparse_mb')} |",
    ]
    d_peak = derived_field(dense, "peak_mb")
    s_peak = derived_field(sparse, "peak_mb")
    if d_peak is not None:
        lines.append(f"| measured peak (MB) | {d_peak} | {s_peak} |")
        lines.append(
            f"| time (µs) | {dense['us_per_call']:.0f} | "
            f"{sparse['us_per_call']:.0f} |"
        )
    else:
        skip = derived_field(dense, "skipped") or "not run"
        lines.append(f"| measured peak (MB) | ✗ ({skip}) | {s_peak} |")
        lines.append(f"| time (µs) | ✗ | {sparse['us_per_call']:.0f} |")
    lines.append(
        f"\nplanner choice: **{derived_field(choice, 'path')}** "
        f"(dense/sparse estimate ratio "
        f"{derived_field(choice, 'dense_over_sparse')})"
    )
    return lines


def distributed_delta_lines(fresh: dict[str, dict]) -> list[str]:
    """Table-11 per-device peak across shard counts as markdown rows."""
    rows = [
        (d, fresh.get(f"table11,STAR,shards_{d}")) for d in (1, 2, 4, 8)
    ]
    if all(rec is None for _, rec in rows):
        return ["_no table-11 records in this run_"]
    lines = [
        "| shards | wall µs | per-device peak (MB) |",
        "|---:|---:|---:|",
    ]
    for d, rec in rows:
        if rec is None:
            continue
        lines.append(
            f"| {d} | {rec['us_per_call']:.0f} | "
            f"{derived_field(rec, 'per_device_peak_mb')} |"
        )
    ratio = derived_field(
        fresh.get("table11,STAR,peak_reduction_1_to_8"), "ratio"
    )
    if ratio is not None:
        lines.append(f"\nper-device peak reduction 1 → 8 shards: **{ratio}**")
    return lines


def estimation_lines(fresh: dict[str, dict]) -> list[str]:
    """Table-13 planner A/B + cost-model estimation accuracy (max
    q-error of estimated vs actual per-node cardinalities) as markdown."""
    tabs = sorted(
        {
            n.split(",")[1]
            for n in fresh
            if n.startswith("table13,") and n.endswith(",estimation")
        }
    )
    if not tabs:
        return ["_no table-13 records in this run_"]
    lines = [
        "| workload | byte peak (MB) | stats peak (MB) | ratio | splits "
        "| max q-error |",
        "|---|---:|---:|---:|---:|---:|",
    ]
    worst = 0.0
    for w in tabs:
        byte = fresh.get(f"table13,{w},byte_heuristic")
        stat = fresh.get(f"table13,{w},stats_planner")
        est = fresh.get(f"table13,{w},estimation")
        q = derived_field(est, "max_qerr")
        worst = max(worst, float(q) if q is not None else 0.0)
        lines.append(
            f"| {w} | {derived_field(byte, 'peak_mb')} "
            f"| {derived_field(stat, 'peak_mb')} "
            f"| {derived_field(stat, 'peak_ratio')}x "
            f"| {derived_field(stat, 'splits')} | {q} |"
        )
    lines.append(f"\nworst per-node cardinality q-error: **{worst:.2f}**")
    return lines


def serving_delta_lines(fresh: dict[str, dict]) -> list[str]:
    """Table-12 serving latency / cache / fusion summary as markdown."""
    cold = fresh.get("table12,SERVE,cold_query")
    warm = fresh.get("table12,SERVE,warm_query")
    load = fresh.get("table12,SERVE,concurrent_load")
    serial = fresh.get("table12,SERVE,serial_repeated")
    fused = fresh.get("table12,SERVE,fused_repeated")
    if not (cold and warm):
        return ["_no table-12 records in this run_"]
    lines = [
        "| metric | value |",
        "|---|---:|",
        f"| cold query (compile + run, µs) | {cold['us_per_call']:.0f} |",
        f"| warm query (plan-cache hit, µs) | {warm['us_per_call']:.0f} |",
    ]
    if load:
        lines += [
            f"| concurrent qps | {derived_field(load, 'qps')} |",
            f"| p50 latency (µs) | {derived_field(load, 'p50_us')} |",
            f"| p99 latency (µs) | {derived_field(load, 'p99_us')} |",
        ]
    if serial and fused:
        lines += [
            f"| serial repeated-shape (µs) | {serial['us_per_call']:.0f} |",
            f"| fused repeated-shape (µs) | {fused['us_per_call']:.0f} |",
        ]
        lines.append(
            f"\ncross-client fusion speedup vs serial: "
            f"**{derived_field(fused, 'speedup_vs_serial')}** "
            f"({derived_field(fused, 'shared_identical')} queries shared "
            f"{derived_field(fused, 'compiles')} compiled plan(s))"
        )
    return lines


def storage_delta_lines(fresh: dict[str, dict]) -> list[str]:
    """Table-14 in-memory vs mmap prepare/execute summary as markdown."""
    prep_m = fresh.get("table14,CHAIN,prepare_mmap")
    prep_i = fresh.get("table14,CHAIN,prepare_inmem")
    if not (prep_m and prep_i):
        return ["_no table-14 records in this run_"]
    lines = [
        "| metric | in-memory | mmap |",
        "|---|---:|---:|",
        f"| prepare peak (MB) | {derived_field(prep_i, 'peak_mb')} | "
        f"{derived_field(prep_m, 'peak_mb')} |",
        f"| prepare peak / largest column | "
        f"{derived_field(prep_i, 'peak_over_col')}x | "
        f"{derived_field(prep_m, 'peak_over_col')}x |",
        f"| prepare (µs) | {prep_i['us_per_call']:.0f} | "
        f"{prep_m['us_per_call']:.0f} |",
    ]
    ex_i = fresh.get("table14,CHAIN,execute_inmem")
    ex_m = fresh.get("table14,CHAIN,execute_mmap")
    if ex_i and ex_m:
        lines.append(
            f"| execute (µs) | {ex_i['us_per_call']:.0f} | "
            f"{ex_m['us_per_call']:.0f} |"
        )
    lines.append(
        f"\nmmap prepare holds "
        f"**{derived_field(prep_m, 'ram_over_mmap_peak')}** less RAM than "
        f"the in-memory path (chunk_rows="
        f"{derived_field(prep_m, 'chunk_rows')})"
    )
    return lines


def fused_delta_lines(fresh: dict[str, dict]) -> list[str]:
    """Table-15 fused-vs-three-dispatch summary as markdown rows."""
    unf = fresh.get("table15,CHAIN,unfused")
    fus = fresh.get("table15,CHAIN,fused")
    if not (unf and fus):
        return ["_no table-15 records in this run_"]
    lines = [
        "| metric | three-dispatch | fused megakernel |",
        "|---|---:|---:|",
        f"| kernel dispatches | {derived_field(unf, 'dispatches')} | "
        f"{derived_field(fus, 'dispatches')} |",
        f"| time (µs) | {unf['us_per_call']:.0f} | "
        f"{fus['us_per_call']:.0f} |",
    ]
    ratio = derived_field(
        fresh.get("table15,CHAIN,dispatch_reduction"), "ratio"
    )
    if ratio is not None:
        lines.append(
            f"\ndispatch reduction from hop fusion: **{ratio}** "
            f"({derived_field(fresh.get('table15,CHAIN,dispatch_reduction'), 'aggs')}"
            "-aggregate bundle, gated ≥1.3x)"
        )
    return lines


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument(
        "--threshold", type=float, default=0.30,
        help="max tolerated normalized per-table regression (default 0.30)",
    )
    ap.add_argument(
        "--min-delta-us", type=float, default=100_000.0,
        help="ignore regressions smaller than this many µs in absolute "
        "terms. Tradeoff: tiny-scale tables of ~150 ms jitter past 30%% "
        "run-to-run even on one machine (observed: +62 ms on table9), "
        "so sub-floor tables are only gated against multi-x blowups; "
        "the multi-second tables carry the fine-grained gate.",
    )
    ap.add_argument(
        "--summary", default=None,
        help="markdown report path (default: $GITHUB_STEP_SUMMARY if set)",
    )
    args = ap.parse_args(argv)

    base = load_records(args.baseline)
    fresh = load_records(args.fresh)
    # a baseline table entirely absent from the fresh run means that
    # bench silently stopped running — fail loudly instead of letting
    # the shared-records intersection hide it forever
    missing = sorted(
        {table_of(n) for n in base} - {table_of(n) for n in fresh},
        key=lambda t: (len(t), t),
    )
    shared = {
        n for n in set(base) & set(fresh)
        if base[n]["us_per_call"] > 0 and fresh[n]["us_per_call"] > 0
    }
    if not shared:
        if missing:
            print(
                "compare: baseline tables missing from the fresh run: "
                + ", ".join(missing),
                file=sys.stderr, flush=True,
            )
            return 1
        print("compare: no shared timed records; nothing to gate", flush=True)
        return 0

    base_tot = table_totals(base, shared)
    fresh_tot = table_totals(fresh, shared)
    base_all = sum(base_tot.values())
    fresh_all = sum(fresh_tot.values())
    speed = fresh_all / max(base_all, 1e-9)

    rows = []
    failures = [
        f"{table}: present in baseline but missing from the fresh run"
        for table in missing
    ]
    for table in sorted(base_tot, key=lambda t: (len(t), t)):
        # leave-one-out normalization: the machine-speed ratio excludes
        # the table under test, so a regression in a time-dominant table
        # cannot inflate the ratio and mask itself
        rest_base = base_all - base_tot[table]
        rest_fresh = fresh_all - fresh_tot[table]
        loo_speed = (
            rest_fresh / rest_base if rest_base > 0 and rest_fresh > 0 else speed
        )
        b, f = base_tot[table] * loo_speed, fresh_tot[table]
        ratio = f / max(b, 1e-9)
        flag = ""
        if ratio > 1 + args.threshold and f - b > args.min_delta_us:
            flag = "**REGRESSION**"
            failures.append(f"{table}: {ratio:.2f}x normalized baseline")
        rows.append(
            f"| {table} | {base_tot[table]:.0f} | {b:.0f} | {f:.0f} "
            f"| {ratio:.2f}x | {flag} |"
        )

    md = [
        "## Bench smoke: perf gate",
        "",
        f"machine-speed normalization: ×{speed:.2f} "
        f"({len(shared)} shared records)",
        "",
        "| table | baseline µs | normalized µs | fresh µs | ratio | |",
        "|---|---:|---:|---:|---:|---|",
        *rows,
        "",
        "### Dense vs sparse jax path (table 10)",
        "",
        *sparse_delta_lines(fresh),
        "",
        "### Distributed-sparse sharding (table 11)",
        "",
        *distributed_delta_lines(fresh),
        "",
        "### Query serving (table 12)",
        "",
        *serving_delta_lines(fresh),
        "",
        "### Statistics-driven planner (table 13)",
        "",
        *estimation_lines(fresh),
        "",
        "### Out-of-core storage tier (table 14)",
        "",
        *storage_delta_lines(fresh),
        "",
        "### Fused hop megakernel (table 15)",
        "",
        *fused_delta_lines(fresh),
        "",
    ]
    if failures:
        md += ["### Failures", ""] + [f"- {f}" for f in failures]

    report = "\n".join(md)
    print(report, flush=True)
    summary = args.summary or os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as fh:
            fh.write(report + "\n")

    if failures:
        print(
            f"compare: {len(failures)} failing table(s) — regressed beyond "
            f"{args.threshold:.0%} or missing from the fresh run",
            file=sys.stderr, flush=True,
        )
        return 1
    print("compare: perf gate green", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
