"""Shared benchmark helpers: wall-time and peak-memory measurement."""
from __future__ import annotations

import time
import tracemalloc
from typing import Callable


def timed(fn: Callable, *args, **kwargs):
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, time.perf_counter() - t0


def peak_memory(fn: Callable, *args, **kwargs):
    """Peak python+numpy allocation during ``fn`` (numpy registers its
    buffers with tracemalloc)."""
    tracemalloc.start()
    tracemalloc.reset_peak()
    out = fn(*args, **kwargs)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return out, peak


def emit(name: str, seconds: float, derived: str) -> str:
    line = f"{name},{seconds * 1e6:.1f},{derived}"
    print(line, flush=True)
    return line


def check_agree(a: dict, b: dict, what: str) -> None:
    assert set(a) == set(b), f"{what}: group sets differ ({len(a)} vs {len(b)})"
    for k, v in a.items():
        assert abs(b[k] - v) <= 1e-6 * max(1.0, abs(v)), (what, k, v, b[k])
