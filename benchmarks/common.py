"""Shared benchmark helpers: wall-time and peak-memory measurement.

Every :func:`emit` line is also collected into :data:`RECORDS` so the
harness can write a machine-readable ``BENCH_*.json`` next to the CSV
stream (:func:`write_json`) — CI uploads it as a per-PR artifact.
"""
from __future__ import annotations

import json
import platform
import time
import tracemalloc
from typing import Callable

RECORDS: list[dict] = []


def timed(fn: Callable, *args, **kwargs):
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, time.perf_counter() - t0


def peak_memory(fn: Callable, *args, **kwargs):
    """Peak python+numpy allocation during ``fn`` (numpy registers its
    buffers with tracemalloc)."""
    tracemalloc.start()
    tracemalloc.reset_peak()
    out = fn(*args, **kwargs)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return out, peak


def emit(name: str, seconds: float, derived: str) -> str:
    line = f"{name},{seconds * 1e6:.1f},{derived}"
    print(line, flush=True)
    RECORDS.append(
        {"name": name, "us_per_call": seconds * 1e6, "derived": derived}
    )
    return line


def write_json(path: str, **meta) -> None:
    """Dump everything emitted so far as one machine-readable document."""
    payload = {
        "meta": {
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
            "python": platform.python_version(),
            **meta,
        },
        "records": RECORDS,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {path} ({len(RECORDS)} records)", flush=True)


def check_agree(a: dict, b: dict, what: str) -> None:
    assert set(a) == set(b), f"{what}: group sets differ ({len(a)} vs {len(b)})"
    for k, v in a.items():
        assert abs(b[k] - v) <= 1e-6 * max(1.0, abs(v)), (what, k, v, b[k])
