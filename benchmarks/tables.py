"""One benchmark per paper table (plus the cyclic GHD suite).

Table I   — dataset characteristics + data-graph load time
Table II / Fig. 8 — peak memory, JOIN-AGG vs pre-aggregation (B2 samples)
Table III — self-join S1–S3, JOIN-AGG vs traditional vs pre-agg
Table IV  — chain C1–C3
Table V   — branching B1–B3
Table VI  — real-shaped queries (TPCH/DBLP/ORDS/IMDB)
Table VII — cyclic graph patterns (triangle / 4-cycle / FOF-group):
            GHD+tensor vs GHD+jax vs the binary-join baseline, which
            materializes the full (quadratic+) intermediate the bag
            decomposition avoids.
Table VIII — incremental maintenance (DESIGN.md §4): refresh latency of
            a MaintainedJoinAgg delta vs full join_agg recompute vs the
            binary-join baseline, across delta sizes 1→10⁴ on the B2
            star query, with peak-delta-bytes accounting.
Table IX  — multi-aggregate bundles (DESIGN.md §6): one fused
            multi-channel pass (COUNT+SUM+MIN+AVG via the logical-plan
            API) vs N separate single-aggregate join_agg runs, time and
            peak allocation, acyclic chain and cyclic triangle.
Table X   — sparse-first jax path (DESIGN.md §7): dense einsum vs
            sparse Pallas execution on a wide-domain chain SUM — time,
            measured peak bytes, the planner's per-path estimates and
            its auto choice.  Past the 2^24-element relation-tensor
            cliff the dense path stops being runnable at all (it raises
            MemoryError); the sparse path is what lets --scale paper
            run the jax engine.  Results verified bit-identical to the
            tensor engine at every scale.
Table XI  — distributed-sparse path (DESIGN.md §8): the same sharded
            program on 1/2/4/8 virtual CPU devices (subprocess — the
            device count must precede jax init) — wall time and
            *measured* per-device bytes (shard-local hop arrays + the
            largest local message).  The root group attribute dominates
            the working set by design, so per-device peak must shrink
            near-linearly: the run asserts ≥3× reduction from 1 → 8
            shards.  Results verified bit-identical to the tensor
            engine when --no-verify is absent.

Table XIV — out-of-core storage tier (DESIGN.md §12): in-memory vs
            disk-backed (memmap) prepare + execute on the measured
            chain — catalog write/open wall time, tracemalloc prepare
            peaks for both paths, and the tier's defining assertion:
            the mmap prepare peak stays below 2× the largest single
            column while the in-memory path's is ~20× it.

Table XV  — fused hop megakernel (DESIGN.md §13): one Pallas launch per
            hop pass (gather + multi-channel product + segment scatter,
            all in VMEM) vs the three-dispatch sparse path on the same
            pinned-sparse plan.  Wall time on CPU runners is an
            interpret-mode artifact, so the gated metric is the
            kernel-dispatch count — the proxy for the launch overhead
            and HBM round-trips fusion removes; verification asserts a
            ≥1.3× dispatch reduction and bit-identical results on a
            COUNT+SUM+MIN+MAX+AVG bundle.

The 'PostgreSQL' column of the paper maps to the in-process traditional
binary-join baseline; all engines are validated to agree on each run.
"""
from __future__ import annotations

from repro.baselines.binary_join import binary_join_agg
from repro.baselines.preagg import preagg_join_agg
from repro.core.operator import join_agg
from repro.core.prepare import prepare
from repro.core.datagraph import build_data_graph
from repro.data import synth
from repro.data.queries import CYCLIC, REAL

from benchmarks.common import check_agree, emit, peak_memory, timed

# beyond this many input rows the binary baseline's materialized cyclic
# intermediates (tens of millions of rows) dominate the whole run
CYCLIC_BASELINE_MAX_N = 5000


def _compare(tag: str, db, q, *, verify: bool, methods=("joinagg", "binary", "preagg")):
    results = {}
    if "joinagg" in methods:
        res, t = timed(join_agg, q, db)
        results["joinagg"] = res
        emit(f"{tag},joinagg", t, f"groups={len(res)}")
    if "binary" in methods:
        (res, stats), t = timed(binary_join_agg, q, db)
        results["binary"] = res
        emit(
            f"{tag},binary", t,
            f"groups={len(res)};max_interm_rows={stats.max_intermediate_rows}",
        )
    if "preagg" in methods:
        (res, stats), t = timed(preagg_join_agg, q, db)
        results["preagg"] = res
        emit(
            f"{tag},preagg", t,
            f"groups={len(res)};max_interm_rows={stats.max_intermediate_rows}",
        )
    if verify and "joinagg" in results:
        for m, r in results.items():
            if m != "joinagg":
                check_agree(results["joinagg"], r, f"{tag}:{m}")


def table1_load(n: int) -> None:
    for name in synth.ALL:
        db, q = synth.make(name, n)
        prep, t_prep = timed(prepare, q, db)
        g, t_graph = timed(build_data_graph, prep)
        emit(
            f"table1,{name},load", t_prep + t_graph,
            f"rows={n};nodes={g.num_nodes};edges={g.num_edges};"
            f"graph_mb={g.memory_bytes() / 1e6:.2f}",
        )


def table2_memory(n: int) -> None:
    """B2 samples P1..P6: peak memory joinagg vs preagg (Fig. 8 / Table II)."""
    sizes = [max(500, n // 16), n // 8, n // 4, n // 2, n]
    for i, sz in enumerate(sizes, start=1):
        db, q = synth.make("B2", sz)
        res_j, mem_j = peak_memory(join_agg, q, db)
        (res_p, stats), mem_p = peak_memory(preagg_join_agg, q, db)
        check_agree(res_j, res_p, f"P{i}")
        emit(
            f"table2,P{i},joinagg_mem", 0.0,
            f"rows={sz};peak_mb={mem_j / 1e6:.2f}",
        )
        emit(
            f"table2,P{i},preagg_mem", 0.0,
            f"rows={sz};peak_mb={mem_p / 1e6:.2f};"
            f"max_interm_rows={stats.max_intermediate_rows}",
        )


def table3_selfjoin(n: int, verify: bool) -> None:
    for name in synth.SELF_JOIN:
        db, q = synth.make(name, n)
        _compare(f"table3,{name}", db, q, verify=verify)


def table4_chain(n: int, verify: bool) -> None:
    for name in synth.CHAIN:
        db, q = synth.make(name, n)
        _compare(f"table4,{name}", db, q, verify=verify)


def table5_branching(n: int, verify: bool) -> None:
    for name in synth.BRANCH:
        db, q = synth.make(name, n)
        _compare(f"table5,{name}", db, q, verify=verify)


def table6_real(n: int, verify: bool) -> None:
    for name, gen in REAL.items():
        db, q = gen(n)
        _compare(f"table6,{name}", db, q, verify=verify)


def table8_incremental(n: int, verify: bool) -> None:
    """Refresh latency vs full recompute vs binary join across delta sizes.

    The maintained handle sees each insert batch; the database is mutated
    in lock-step so the full-recompute and baseline timings answer the
    *same* query.  With verification on, the refreshed result must be
    bit-identical to the from-scratch one."""
    import numpy as np

    from repro.incremental import MaintainedJoinAgg

    db, q = synth.make("B2", n)
    handle, t_prep = timed(MaintainedJoinAgg, q, db)
    emit("table8,B2,prepare", t_prep, f"rows={n}")
    rng = np.random.default_rng(11)
    sel1, sel2 = synth.BRANCH["B2"]
    jdom, bdom = max(2, int(sel1 * n)), max(2, int(sel2 * n))
    for dsize in (1, 10, 100, 1000, 10000):
        if dsize > n:
            break
        delta = {
            "j": rng.integers(0, jdom, dsize),
            "b": rng.integers(0, bdom, dsize),
        }
        _, t_refresh = timed(handle.insert, "R2", delta)
        r2 = db.relations["R2"].columns
        r2["j"] = np.concatenate([r2["j"], delta["j"]])
        r2["b"] = np.concatenate([r2["b"], delta["b"]])
        full, t_full = timed(join_agg, q, db)
        if verify:
            assert handle.result() == full, f"d{dsize}: refresh not identical"
        emit(
            f"table8,B2,refresh_d{dsize}", t_refresh,
            f"speedup_vs_full={t_full / t_refresh:.1f}x;"
            f"peak_delta_mb={handle.stats.peak_delta_bytes / 1e6:.3f};"
            f"rows_rescanned={handle.stats.rows_rescanned}",
        )
        emit(f"table8,B2,full_recompute_d{dsize}", t_full, f"groups={len(full)}")
    (res_b, stats), t_bin = timed(binary_join_agg, q, db)
    emit(
        "table8,B2,binary", t_bin,
        f"groups={len(res_b)};max_interm_rows={stats.max_intermediate_rows}",
    )
    if verify:
        check_agree(handle.result(), res_b, "table8:binary")


def table9_multiagg(n: int, verify: bool) -> None:
    """One fused multi-aggregate pass vs N independent single-agg runs.

    The bundle (COUNT, SUM, MIN, AVG over one measure) runs as two
    semiring channels + one reachability pass through the logical-plan
    API; the baseline runs the same aggregates as four separate
    ``join_agg`` calls.  Time and tracemalloc peak are reported for both,
    on an acyclic chain and (at reduced scale) a cyclic triangle."""
    import numpy as np

    from repro.aggregates.semiring import Avg, Count, Min, Sum
    from repro.api import Q
    from repro.core.query import JoinAggQuery
    from repro.data.queries import triangle_like

    rng = np.random.default_rng(17)
    jdom, gdom = max(2, n // 20), max(2, n // 50)
    db = _measured_chain_db(rng, n, jdom, gdom)
    cases = {
        "CHAIN": (
            db,
            ("R1", "R2", "R3"),
            (("R1", "g1"), ("R3", "g2")),
            {
                "count": Count(),
                "total": Sum("R2.m"),
                "lo": Min("R2.m"),
                "mean": Avg("R2.m"),
            },
        )
    }
    tri_db, tri_q = triangle_like(max(200, n // 4))
    tri_db["E1"].columns["w"] = rng.integers(1, 9, tri_db["E1"].num_rows)
    cases["TRIANGLE"] = (
        tri_db,
        tri_q.relations,
        tri_q.group_by,
        {
            "count": Count(),
            "total": Sum("E1.w"),
            "lo": Min("E1.w"),
            "mean": Avg("E1.w"),
        },
    )

    for tag, (cdb, rels, group_by, aggs) in cases.items():
        q = Q.over(*rels).group_by(*group_by).agg(**aggs)
        plan = q.plan(cdb)
        (res, mem_multi), t_multi = timed(peak_memory, plan.execute)
        emit(
            f"table9,{tag},multiagg_pass", t_multi,
            f"aggs={len(aggs)};groups={res.num_rows};"
            f"peak_mb={mem_multi / 1e6:.2f}",
        )

        def run_separate(cdb=cdb, rels=rels, group_by=group_by, aggs=aggs):
            return {
                name: join_agg(JoinAggQuery(rels, group_by, agg), cdb)
                for name, agg in aggs.items()
            }

        (sep, mem_sep), t_sep = timed(peak_memory, run_separate)
        emit(
            f"table9,{tag},separate_runs", t_sep,
            f"aggs={len(aggs)};speedup_of_fused={t_sep / t_multi:.2f}x;"
            f"peak_mb={mem_sep / 1e6:.2f}",
        )
        if verify:
            for name in aggs:
                check_agree(res.to_dict(name), sep[name], f"table9,{tag}:{name}")


def _measured_chain_db(rng, n, jdom, gdom):
    from repro.relational.relation import Database

    return Database.from_mapping(
        {
            "R1": {
                "g1": rng.integers(0, gdom, n),
                "p0": rng.integers(0, jdom, n),
            },
            "R2": {
                "p0": rng.integers(0, jdom, n),
                "p1": rng.integers(0, jdom, n),
                "m": rng.integers(1, 100, n),
            },
            "R3": {
                "p1": rng.integers(0, jdom, n),
                "g2": rng.integers(0, gdom, n),
            },
        }
    )


def table10_sparse(n: int, verify: bool) -> None:
    """Dense-vs-sparse jax execution (see module docstring, Table X).

    Join domains scale with n (jdom = n/5), so the per-relation dense
    tensor is ~(n/5)² f32 elements: tiny/small stay under the 2^24
    promotion cliff (both paths run and are measured against each
    other), while medium and paper cross it — auto picks sparse, the
    dense measurement is skipped (its relation tensors alone would
    dwarf the whole sparse run) and only the planner's dense estimate
    is reported."""
    import numpy as np

    from repro.aggregates.semiring import Sum
    from repro.core.jax_engine import choose_jax_path, execute_jax
    from repro.core.operator import peak_message_bytes
    from repro.core.prepare import prepare
    from repro.core.query import JoinAggQuery
    from repro.core.tensor_engine import execute_tensor

    from repro.core.jax_engine import DENSE_PROMOTE_ELEMS

    rng = np.random.default_rng(23)
    jdom, gdom = max(4, n // 5), max(2, n // 50)
    db = _measured_chain_db(rng, n, jdom, gdom)
    q = JoinAggQuery(
        ("R1", "R2", "R3"), (("R1", "g1"), ("R3", "g2")), Sum("R2", "m")
    )
    prep = prepare(q, db)
    choice = choose_jax_path(prep)
    emit(
        "table10,CHAIN,auto_choice", 0.0,
        f"path={choice.path};est_dense_mb={choice.dense_peak / 1e6:.2f};"
        f"est_sparse_mb={choice.sparse_peak / 1e6:.2f};"
        f"dense_over_sparse={choice.dense_peak / max(choice.sparse_peak, 1):.1f}x",
    )

    execute_jax(q, db, prep=prep, mode="sparse")  # warmup: jax init + jit
    (res_s, mem_s), t_s = timed(
        peak_memory, execute_jax, q, db, prep=prep, mode="sparse"
    )
    emit(
        "table10,CHAIN,jax_sparse", t_s,
        f"groups={len(res_s)};peak_mb={mem_s / 1e6:.2f}",
    )
    # Bit-identity vs the exact f64 engine whenever its oracle run is
    # affordable (it is at tiny→medium; at paper scale the tensor
    # engine's own peak message is multi-GB, the exact thing only the
    # sparse path avoids — skipping there is the point of the table).
    # Deliberately independent of the --no-verify flag: exact equality
    # is this table's claim, and check_agree's 1e-6 tolerance would
    # weaken it — hence the explicit raise (assert would vanish under
    # `python -O`).
    if peak_message_bytes(prep) <= 1 << 30:
        want = execute_tensor(q, db, prep=prep)
        if res_s != want:
            raise AssertionError(
                "sparse jax result not bit-identical to tensor engine"
            )
    else:
        emit(
            "table10,CHAIN,tensor_verify", 0.0,
            "skipped=tensor_peak_exceeds_1GiB",
        )

    max_elems = max(
        int(np.prod([prep.dicts[a].size for a in er.attrs]))
        for er in prep.encoded.values()
    )
    if max_elems > DENSE_PROMOTE_ELEMS:
        # past the cliff the dense relation tensors alone dwarf the whole
        # sparse run; don't burn the bench budget materializing them
        emit(
            "table10,CHAIN,jax_dense", 0.0,
            f"skipped=dense_cliff;max_relation_elems={max_elems}",
        )
        return
    try:
        execute_jax(q, db, prep=prep, mode="dense")  # warmup: trace + compile
        (res_d, mem_d), t_d = timed(
            peak_memory, execute_jax, q, db, prep=prep, mode="dense"
        )
    except MemoryError as e:
        emit("table10,CHAIN,jax_dense", 0.0, f"skipped=dense_cliff:{e}")
        return
    emit(
        "table10,CHAIN,jax_dense", t_d,
        f"groups={len(res_d)};peak_mb={mem_d / 1e6:.2f};"
        f"sparse_peak_ratio={mem_s / max(mem_d, 1):.3f}",
    )
    if verify:
        check_agree(res_s, res_d, "table10:dense")


_TABLE11_SCRIPT = r"""
import json
import sys
import time

import numpy as np

from repro.api import Count, Q, Sum
from repro.core.distributed import build_distributed_program
from repro.relational.relation import Database

n, do_verify = int(sys.argv[1]), sys.argv[2] == "1"
rng = np.random.default_rng(31)
n23 = max(256, n // 10)
pdom = max(4, n23 // 8)
db = Database.from_mapping({
    # the root relation dominates: one row per source draw over a dense
    # source domain (the paper's per-source outer loop is what shards)
    "R1": {"g1": rng.integers(0, n, n), "p": rng.integers(0, pdom, n)},
    "R2": {
        "p": rng.integers(0, pdom, n23),
        "q": rng.integers(0, pdom, n23),
        "m": rng.integers(1, 8, n23),
    },
    "R3": {"q": rng.integers(0, pdom, n23), "g2": rng.integers(0, 8, n23)},
})
q = (
    Q.over("R1", "R2", "R3")
    .group_by("R1.g1", "R3.g2")
    .agg(c=Count(), total=Sum("R2.m"))
)
plan = q.engine("jax").plan(db)
cm = tuple(
    ch.measure[0] if ch.kind == "sum" else None for ch in plan.channels
)
rows = []
for d in (1, 2, 4, 8):
    prog = build_distributed_program(plan.prep, cm, d)
    prog.run()  # warmup: device_put + shard_map trace + compile
    t0 = time.perf_counter()
    outs = prog.run()
    wall = time.perf_counter() - t0
    groups = int(sum((arr[..., 0] > 0).sum() for arr, _, _ in outs))
    verified = None
    if do_verify:
        got = plan.execute(mesh=d)
        want = q.engine("tensor").plan(db).execute()
        verified = got.group_tuples() == want.group_tuples() and all(
            got.to_dict(name) == want.to_dict(name) for name in ("c", "total")
        )
    rows.append({
        "devices": d,
        "wall_s": wall,
        "per_device_bytes": prog.per_device_bytes(),
        "groups": groups,
        "verified": verified,
    })
print(json.dumps({"rows": rows}))
"""


def table11_distributed(n: int, verify: bool) -> None:
    """Sharded sparse JOIN-AGG over 1/2/4/8 virtual devices (Table XI).

    One subprocess (8 virtual CPU devices fixed before jax init) builds
    the same star-chain plan on meshes of 1/2/4/8 shards and reports
    wall time + measured per-device bytes; this side emits the records
    and enforces the near-linear peak reduction the sharding exists for.
    """
    import json
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("JAX_PLATFORMS", "cpu")
    res = subprocess.run(
        [sys.executable, "-c", _TABLE11_SCRIPT, str(n), "1" if verify else "0"],
        capture_output=True,
        text=True,
        env=env,
        timeout=1800,
    )
    if res.returncode != 0:
        raise RuntimeError(
            f"table11 subprocess failed:\n{res.stderr[-3000:]}"
        )
    rows = json.loads(res.stdout.strip().splitlines()[-1])["rows"]
    by_dev = {}
    for row in rows:
        by_dev[row["devices"]] = row
        emit(
            f"table11,STAR,shards_{row['devices']}", row["wall_s"],
            f"groups={row['groups']};"
            f"per_device_peak_mb={row['per_device_bytes'] / 1e6:.3f}"
            + ("" if row["verified"] is None else f";verified={row['verified']}"),
        )
        if verify and row["verified"] is not True:
            raise AssertionError(
                f"table11: sharded result on {row['devices']} device(s) "
                "not bit-identical to the tensor engine"
            )
    ratio = by_dev[1]["per_device_bytes"] / max(by_dev[8]["per_device_bytes"], 1)
    emit(
        "table11,STAR,peak_reduction_1_to_8", 0.0,
        f"ratio={ratio:.2f}x",
    )
    if n >= 1000 and ratio < 3.0:
        raise AssertionError(
            f"table11: per-device peak shrank only {ratio:.2f}x from "
            "1 -> 8 shards (expected >= 3x)"
        )


def table14_storage(n: int, verify: bool) -> None:
    """Table XIV — out-of-core storage tier (DESIGN.md §12): in-memory
    vs disk-backed (memmap) execution of the fold-free measured chain.

    Reports write/open wall time for the on-disk catalog, then prepare
    (dictionaries + streaming encode + grouped-CSR build) and execute
    wall time plus tracemalloc peak for both paths.  The number the tier
    exists for: the mmap prepare's peak allocation must stay below 2×
    the largest single column of the database — the streaming encode and
    the external k-way merge never hold a relation in RAM (the in-memory
    path's peak is ~20× the same column).  tracemalloc does not count
    memmap-backed buffers, which is exactly the point: what it measures
    is the RAM the process actually commits.  The assertion is
    unconditional (like table 10's bit-identity check) because the peak
    is allocation-determined, not timing-noise; result equality with the
    in-memory run gates only under --no-verify's inverse.
    """
    import shutil
    import tempfile

    import numpy as np

    from repro.aggregates.semiring import Sum
    from repro.core.query import JoinAggQuery

    # the out-of-core story needs rows: below ~100k the fixed overheads
    # of the streaming machinery dwarf a "largest column" of a few KB,
    # so the table runs at medium scale even under --scale tiny
    n = max(n, 100_000)
    rng = np.random.default_rng(41)
    jdom, gdom = max(4, n // 50), 32
    db = _measured_chain_db(rng, n, jdom, gdom)
    q = JoinAggQuery(
        ("R1", "R2", "R3"), (("R1", "g1"), ("R3", "g2")), Sum("R2", "m")
    )
    col_bytes = max(
        c.nbytes for r in db.relations.values() for c in r.columns.values()
    )

    from repro.storage import open_database, write_database

    tmp = tempfile.mkdtemp(prefix="repro-bench-t14-")
    try:
        _, t_write = timed(write_database, db, tmp + "/db")
        emit(
            "table14,CHAIN,write_database", t_write,
            f"rows={n};largest_col_mb={col_bytes / 1e6:.2f}",
        )
        mdb, t_open = timed(open_database, tmp + "/db")
        emit("table14,CHAIN,open_database", t_open, f"relations={len(db.relations)}")

        chunk = max(4096, n // 25)

        def prep_all(d, ch):
            prep = prepare(q, d, chunk_rows=ch)
            for rel, attr in (("R1", "p0"), ("R2", "p0"), ("R3", "p1")):
                prep.csr_view(rel, (attr,))
            return prep

        (_, mem_mmap), t_pm = timed(peak_memory, prep_all, mdb, chunk)
        (_, mem_ram), t_pi = timed(peak_memory, prep_all, db, None)
        emit(
            "table14,CHAIN,prepare_inmem", t_pi,
            f"peak_mb={mem_ram / 1e6:.2f};"
            f"peak_over_col={mem_ram / col_bytes:.2f}",
        )
        emit(
            "table14,CHAIN,prepare_mmap", t_pm,
            f"peak_mb={mem_mmap / 1e6:.2f};"
            f"peak_over_col={mem_mmap / col_bytes:.2f};"
            f"chunk_rows={chunk};"
            f"ram_over_mmap_peak={mem_ram / max(mem_mmap, 1):.1f}x",
        )
        if mem_mmap >= 2 * col_bytes:
            raise AssertionError(
                f"table14: mmap prepare peak {mem_mmap / 1e6:.2f}MB is not "
                f"below 2x the largest column ({col_bytes / 1e6:.2f}MB)"
            )
        res_i, t_ei = timed(join_agg, q, db)
        res_m, t_em = timed(join_agg, q, mdb)
        emit("table14,CHAIN,execute_inmem", t_ei, f"groups={len(res_i)}")
        emit(
            "table14,CHAIN,execute_mmap", t_em,
            f"groups={len(res_m)};mmap_over_inmem={t_em / max(t_ei, 1e-9):.2f}",
        )
        if verify and res_i != res_m:
            raise AssertionError(
                "table14: disk-backed result not bit-identical to in-memory"
            )
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def table15_fused(n: int, verify: bool) -> None:
    """Table XV — fused hop megakernel vs three-dispatch (DESIGN.md §13).

    Same plan both sides: sparse path pinned via a 1-byte memory budget,
    a 5-aggregate bundle so the sum pass carries multiple channels and
    the min/max passes run too.  Each side is warmed (build + trace +
    jit memos), then one timed execute with the host-side dispatch
    counters reset — the dispatch total is the launch-overhead/HBM
    round-trip proxy the fusion exists to cut, and the only number
    stable across runner hardware."""
    import numpy as np

    from repro.aggregates.semiring import Avg, Count, Max, Min, Sum
    from repro.api import Q
    from repro.kernels import ops

    rng = np.random.default_rng(47)
    jdom, gdom = max(2, n // 20), max(2, n // 50)
    db = _measured_chain_db(rng, n, jdom, gdom)
    q = (
        Q.over("R1", "R2", "R3")
        .group_by("R1.g1", "R3.g2")
        .agg(
            c=Count(),
            total=Sum("R2.m"),
            lo=Min("R2.m"),
            hi=Max("R2.m"),
            mean=Avg("R2.m"),
        )
        .engine("jax")
        .memory_budget(1)  # pin the sparse path: a pure fused-vs-not A/B
    )
    runs = {}
    for tag, fused in (("unfused", False), ("fused", True)):
        plan = q.fused(fused).plan(db)
        plan.execute()  # warmup: program build + trace + compile memos
        ops.reset_dispatch_counts()
        res, t = timed(plan.execute)
        counts = ops.dispatch_counts()
        runs[tag] = (res, sum(counts.values()))
        emit(
            f"table15,CHAIN,{tag}", t,
            f"groups={res.num_rows};dispatches={sum(counts.values())};"
            + ";".join(f"n_{k}={v}" for k, v in sorted(counts.items())),
        )
    (res_u, d_u), (res_f, d_f) = runs["unfused"], runs["fused"]
    ratio = d_u / max(d_f, 1)
    emit(
        "table15,CHAIN,dispatch_reduction", 0.0,
        f"ratio={ratio:.2f}x;aggs=5",
    )
    if verify:
        for name in res_u.agg_names:
            if res_f.to_dict(name) != res_u.to_dict(name):
                raise AssertionError(
                    f"table15: fused result for {name!r} not bit-identical "
                    "to the three-dispatch path"
                )
        if ratio < 1.3:
            raise AssertionError(
                f"table15: fused path cut dispatches only {ratio:.2f}x "
                "below three-dispatch (expected >= 1.3x)"
            )


def table7_cyclic(n: int, verify: bool) -> None:
    """Cyclic suite: GHD-compiled engines vs the traditional baseline.

    Compilation (bag materialization) is timed once and the plan reused
    across engines, mirroring how a resident system would amortize it."""
    from repro.core.operator import peak_message_bytes
    from repro.ghd.rewrite import compile_ghd, ghd_join_agg

    for name, gen in CYCLIC.items():
        db, q = gen(n)
        plan, t_compile = timed(compile_ghd, q, db)
        peak = max(plan.bag_peak_bytes, peak_message_bytes(plan.prepared))
        emit(
            f"table7,{name},ghd_compile", t_compile,
            f"bags={len(plan.derived_query.relations)};"
            f"est_peak_mb={peak / 1e6:.2f}",
        )
        res_t, t_tensor = timed(ghd_join_agg, q, db, engine="tensor", plan=plan)
        emit(f"table7,{name},ghd_tensor", t_tensor, f"groups={len(res_t)}")
        res_j, t_jax = timed(ghd_join_agg, q, db, engine="jax", plan=plan)
        emit(f"table7,{name},ghd_jax", t_jax, f"groups={len(res_j)}")
        if verify:
            check_agree(res_t, res_j, f"table7,{name}:jax")
        if n > CYCLIC_BASELINE_MAX_N:
            emit(f"table7,{name},binary", 0.0, "skipped=intermediate_blowup")
            continue
        try:
            (res_b, stats), t_bin = timed(binary_join_agg, q, db)
        except ValueError as e:  # e.g. FOFGROUP: group attr joins
            emit(f"table7,{name},binary", 0.0, f"skipped={e}")
            continue
        emit(
            f"table7,{name},binary", t_bin,
            f"groups={len(res_b)};max_interm_rows={stats.max_intermediate_rows}",
        )
        if verify:
            check_agree(res_t, res_b, f"table7,{name}:binary")


def table12_serving(n: int, verify: bool) -> None:
    """Table XII — query serving (DESIGN.md §9): latency percentiles and
    throughput of the concurrent JOIN-AGG server.

    Four measurements on the C1 chain:

    * cold vs warm prepared-plan cache — first query pays logical
      rewrites + root search + compile, the repeat is a cache hit;
    * p50/p99 latency + qps under concurrent mixed-shape load;
    * fused vs serial throughput on repeated-shape load — N identical
      queries landing in one fusion window execute as ONE contraction
      pass, so the fused wall time must beat running them serially
      (asserted ≥1.5× when verifying).
    """
    import statistics
    import threading
    import time as _time

    from repro.aggregates.semiring import Avg, Count, Sum
    from repro.api.builder import Q
    from repro.api.plan import compile_plan
    from repro.serve.server import JoinAggServer

    import numpy as np

    db, _ = synth.chain("C1", n, seed=0)
    rng = np.random.default_rng(1)
    r2 = db["R2"]
    db.add(r2.with_column("w", rng.integers(1, 100, r2.num_rows)))

    base = Q.over("R1", "R2", "R3", "R4")
    queries = {
        "count": base.group_by("R1.g1").agg(c=Count()),
        "sum": base.group_by("R1.g1").agg(total=Sum("R2.w")),
        "multi": base.group_by("R4.g2").agg(
            c=Count(), total=Sum("R2.w"), mean=Avg("R2.w")
        ),
    }
    oracles = {
        k: compile_plan(q, db).execute().to_dict(
            compile_plan(q, db).execute().agg_names[0]
        )
        for k, q in queries.items()
    } if verify else {}

    # -- cold vs warm plan cache ---------------------------------------
    srv = JoinAggServer(db, workers=4, fusion_window=0.002)
    res_cold, t_cold = timed(srv.query, queries["count"])
    res_warm, t_warm = timed(srv.query, queries["count"])
    pc = srv.plan_cache.stats.snapshot()
    emit(
        "table12,SERVE,cold_query", t_cold,
        f"compiles={pc['compiles']};groups={res_cold.num_rows}",
    )
    emit(
        "table12,SERVE,warm_query", t_warm,
        f"cache_hits={pc['hits']};warm_over_cold={t_warm / max(t_cold, 1e-9):.3f}",
    )
    if verify:
        assert pc["compiles"] == 1, "warm repeat recompiled the plan"
        a = res_cold.agg_names[0]
        assert res_warm.to_dict(a) == oracles["count"]

    # -- latency under concurrent mixed-shape load ---------------------
    clients, per_client = 6, 8
    latencies: list[float] = []
    lat_lock = threading.Lock()
    bad: list[str] = []

    def client(i: int) -> None:
        names = list(queries)
        for j in range(per_client):
            name = names[(i + j) % len(names)]
            t0 = _time.perf_counter()
            res = srv.query(queries[name])
            dt = _time.perf_counter() - t0
            with lat_lock:
                latencies.append(dt)
                if verify and res.to_dict(res.agg_names[0]) != oracles[name]:
                    bad.append(name)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(clients)]
    t0 = _time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = _time.perf_counter() - t0
    if verify and bad:
        raise AssertionError(f"table12: served results diverged: {bad}")
    total = clients * per_client
    lat = sorted(latencies)
    p50 = statistics.median(lat)
    p99 = lat[min(len(lat) - 1, int(0.99 * len(lat)))]
    emit(
        "table12,SERVE,concurrent_load", wall,
        f"clients={clients};queries={total};qps={total / wall:.1f};"
        f"p50_us={p50 * 1e6:.0f};p99_us={p99 * 1e6:.0f}",
    )
    srv.close()

    # -- fused vs serial on repeated-shape load ------------------------
    # Sustained closed-loop load, not a single burst: every fusion batch
    # serves ~hot_clients queries at one contraction's cost, so steady
    # throughput — not burst latency, which always pays the window — is
    # where cross-client fusion shows up.
    hot_clients, hot_per = 16, 8
    total_hot = hot_clients * hot_per
    q_hot = queries["sum"]
    plan_hot = compile_plan(q_hot, db)
    plan_hot.execute()  # warm the engine memos outside the timed region

    def serial() -> None:
        for _ in range(total_hot):
            plan_hot.execute()

    # the window only needs to cover queries arriving while the previous
    # batch executes; oversizing it adds latency without adding sharing
    srv2 = JoinAggServer(db, workers=4, fusion_window=0.0005)
    srv2.query(q_hot)  # warm plan cache + memos

    def hot_client() -> None:
        for _ in range(hot_per):
            srv2.query(q_hot)

    def fused() -> None:
        threads = [
            threading.Thread(target=hot_client) for _ in range(hot_clients)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    # best-of-3 on both sides: these are wall-clock throughput numbers
    # on a shared box, and a single noisy run should not gate CI
    rounds = 3
    t_serial = min(timed(serial)[1] for _ in range(rounds))
    t_fused = min(timed(fused)[1] for _ in range(rounds))
    fstats = srv2.plan_cache.stats.snapshot()
    bstats = srv2._batcher.stats.snapshot()
    srv2.close()
    speedup = t_serial / max(t_fused, 1e-9)
    emit(
        "table12,SERVE,serial_repeated", t_serial,
        f"queries={total_hot};rounds={rounds};qps={total_hot / t_serial:.1f}",
    )
    emit(
        "table12,SERVE,fused_repeated", t_fused,
        f"queries={total_hot};rounds={rounds};qps={total_hot / t_fused:.1f};"
        f"batches={bstats['batches']};"
        f"shared_identical={bstats['shared_identical']};"
        f"compiles={fstats['compiles']};speedup_vs_serial={speedup:.2f}x",
    )
    if verify and speedup < 1.5:
        raise AssertionError(
            f"table12: cross-client fusion sped repeated-shape load up only "
            f"{speedup:.2f}x over serial (expected >= 1.5x)"
        )


def table13_planner(n: int, verify: bool) -> None:
    """Table XIII — statistics-driven planner (DESIGN.md §10): the
    skewed-chain A/B.  The byte-heuristic plan (``Q.stats(False)``) runs
    the dense contraction over the full skewed join-key domain; the
    statistics-driven plan detects the heavy hitter, splits the key space
    into heavy singletons + light chunks, and executes per range.

    Emits measured (tracemalloc) peak bytes for both plans plus the cost
    model's estimation accuracy (max q-error of estimated vs actual
    per-node message cardinalities).  When verifying: the split plan must
    measure ≥2× below the byte-heuristic plan's peak and both must match
    the tensor oracle exactly.
    """
    from repro.api.builder import Q
    from repro.core.tensor_engine import execute_tensor
    from repro.data.queries import SKEWED
    from repro.planner.cost import actual_node_cards, node_card_estimates, qerror

    for name, gen in SKEWED.items():
        db, q = gen(n, seed=0)
        plan_b = Q.from_query(q).stats(False).plan(db)
        plan_s = Q.from_query(q).plan(db)
        if plan_s.split is None:
            raise AssertionError(
                f"table13,{name}: stats planner found no qualifying skew"
            )
        if plan_b.split is not None:
            raise AssertionError(
                f"table13,{name}: byte-heuristic plan must not split"
            )
        (res_b, mem_b), t_b = timed(peak_memory, plan_b.execute)
        (res_s, mem_s), t_s = timed(peak_memory, plan_s.execute)
        ratio = mem_b / max(mem_s, 1)
        emit(
            f"table13,{name},byte_heuristic", t_b,
            f"peak_mb={mem_b / 1e6:.2f};groups={res_b.num_rows}",
        )
        emit(
            f"table13,{name},stats_planner", t_s,
            f"peak_mb={mem_s / 1e6:.2f};splits={plan_s.split.num_splits};"
            f"heavy_keys={len(plan_s.split.heavy)};peak_ratio={ratio:.2f}",
        )
        ests = node_card_estimates(plan_s.prep, plan_s.prep.stats)
        acts, t_est = timed(actual_node_cards, plan_s.prep)
        max_q = max(qerror(ests[r], acts[r]) for r in ests)
        emit(
            f"table13,{name},estimation", t_est,
            f"max_qerr={max_q:.2f};nodes={len(ests)}",
        )
        if verify:
            oracle = execute_tensor(q, db)
            d_s, d_b = res_s.to_dict(), res_b.to_dict()
            if d_s != oracle:
                raise AssertionError(
                    f"table13,{name}: split plan diverged from tensor oracle"
                )
            if d_b != oracle:
                raise AssertionError(
                    f"table13,{name}: byte plan diverged from tensor oracle"
                )
            if ratio < 2.0:
                raise AssertionError(
                    f"table13,{name}: stats plan cut measured peak only "
                    f"{ratio:.2f}x below the byte heuristic (expected >= 2x)"
                )
